"""Deterministic fault injection at named sites (DESIGN.md §16).

Recovery paths are only trustworthy if they are *exercised*: this module
lets tier-1 tests make a specific failure happen at a specific, repeatable
point — the second sample batch raises, the first checkpoint write dies
between the tmp write and the rename, every compact dispatch overflows —
without monkeypatching internals or relying on timing races.

Instrumented sites (grep ``faults.fire`` for the authoritative list):

=========================  ====================================================
``sample.raise``           a supervised sample attempt raises :class:`InjectedFault`
``sample.timeout``         a supervised sample attempt sleeps past the policy
                           timeout (``payload`` seconds; default 4x the policy)
``sample.nan``             the returned sample payload is poisoned with NaN
``sample.negative``        the returned payload contains a negative count
``checkpoint.write_crash``  :meth:`CheckpointManager._write` raises
                           :class:`InjectedCrash` after writing ``step_*.tmp``
                           but before the atomic rename (kill mid-save)
``estimator.kill``         the estimation loop raises :class:`InjectedCrash`
                           immediately after a checkpoint save (kill between
                           checkpoints)
``compaction.overflow``    the §15 speculate-check wrapper treats the batch as
                           overflowed and re-dispatches the dense twin
``compression.saturate``   the §18 narrow-wire wrapper treats the batch as
                           saturated and re-dispatches the wider-wire twin
                           (int8 -> int16 -> float32 escalation ladder)
``service.step_crash``     :meth:`CountingService.step` raises
                           :class:`InjectedFault` before scheduling anything
                           (the §20 driver thread must record it and survive)
``service.pass_poison``    one coalesced pass call's backend payload is
                           poisoned with NaN — a §16 hard fault: the call
                           quarantines without killing co-riding requests
``service.slow_pass``      one coalesced pass call sleeps ``payload`` seconds
                           (default 4x the service timeout) so the service
                           supervisor's per-batch timeout fires and retries
=========================  ====================================================

Usage::

    from repro.testing import faults

    with faults.active(faults.inject("sample.raise", at=(0, 1))):
        ...  # the first two occurrences of the site raise; the third runs

``at`` indexes *occurrences* of the site (0-based, counted per activation);
``at=None`` fires every occurrence (persistent failure).  Activation is
process-global and re-entrant-unsafe by design — tests activate exactly one
plan at a time; occurrence counters reset on each activation.  When no plan
is active every hook is a single ``is None`` check.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Iterable, Optional, Tuple

__all__ = [
    "FaultSpec",
    "FaultPlan",
    "InjectedFault",
    "InjectedCrash",
    "inject",
    "active",
    "fire",
    "is_active",
]


class InjectedFault(RuntimeError):
    """A *transient* injected failure (retryable — e.g. a sample raise)."""


class InjectedCrash(RuntimeError):
    """A *fatal* injected failure simulating a process kill.

    Raised by the ``checkpoint.write_crash`` and ``estimator.kill`` sites;
    product code never catches it, so it unwinds like SIGKILL would (minus
    the actual process exit), leaving on-disk state exactly as a real kill
    at that point leaves it.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``site`` at the given occurrence indices."""

    site: str
    at: Optional[frozenset] = frozenset({0})  # None = every occurrence
    payload: Any = None  # site-specific (e.g. sleep seconds for a timeout)

    def fires(self, occurrence: int) -> bool:
        return self.at is None or occurrence in self.at


def inject(
    site: str,
    at: Optional[Iterable[int]] = (0,),
    payload: Any = None,
) -> FaultSpec:
    """Schedule ``site`` to fault at the given occurrence indices."""
    return FaultSpec(site, None if at is None else frozenset(at), payload)


class FaultPlan:
    """A set of :class:`FaultSpec` plus per-site occurrence counters."""

    def __init__(self, *specs: FaultSpec):
        self._specs: Dict[str, Tuple[FaultSpec, ...]] = {}
        for s in specs:
            self._specs[s.site] = self._specs.get(s.site, ()) + (s,)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()  # writer threads / timed attempts fire too
        self.fired: list = []  # (site, occurrence) log, for test assertions

    def fire(self, site: str) -> Optional[FaultSpec]:
        if site not in self._specs:
            return None
        with self._lock:
            occ = self._counts.get(site, 0)
            self._counts[site] = occ + 1
            for spec in self._specs[site]:
                if spec.fires(occ):
                    self.fired.append((site, occ))
                    return spec
        return None


_ACTIVE: Optional[FaultPlan] = None


def is_active() -> bool:
    return _ACTIVE is not None


def fire(site: str) -> Optional[FaultSpec]:
    """The hook product code calls at a named site.

    Returns the matching :class:`FaultSpec` when the active plan schedules a
    fault for this occurrence, else ``None``.  A single ``is None`` check
    when no plan is active — the instrumented hot paths pay nothing.
    """
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.fire(site)


@contextlib.contextmanager
def active(*specs: FaultSpec):
    """Activate a fault plan for the duration of the block.

    Yields the :class:`FaultPlan` (its ``fired`` log is useful for asserting
    that a site was actually reached).  Occurrence counters start at zero.
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a fault plan is already active (no nesting)")
    plan = FaultPlan(*specs)
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None
