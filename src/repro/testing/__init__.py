"""Test-support layer: deterministic fault injection (``repro.testing.faults``).

Nothing in here runs unless a test (or an operator debugging a recovery
path) explicitly activates it; the hooks compiled into the product code
are a single ``is None`` check when inactive.
"""

from . import faults  # noqa: F401

__all__ = ["faults"]
