"""Offline approximation of ``ruff format`` used for the mechanical pass.

The CI format gate runs the real ``ruff format --check src tests benchmarks
examples tools`` (lint job); this tool exists because the dev container has
no network and no ruff wheel, yet the tree still needs to be *brought to*
ruff style in a mechanical, reviewable commit.  It implements the subset of
rules that account for every deviation found in the tree:

  * collapse a multi-line bracketed statement to one line when it fits in
    the configured ``line-length`` (100, from pyproject) and carries no
    magic trailing comma — dropping a now-redundant ``= (...)`` /
    ``return (...)`` paren pair;
  * explode a construct whose outermost bracket carries a magic trailing
    comma to one element per line (ruff's magic-trailing-comma contract),
    and explode single-line statements that overflow the limit, adding the
    trailing comma ruff adds;
  * normalize simple single-quoted strings to double quotes, strip
    trailing whitespace, and end files with exactly one newline.

Anything it cannot prove safe it leaves untouched: logical lines holding
comments, multi-line or implicitly-concatenated strings, lambdas (their
argument commas are unbracketed), or more than one top-level bracket
group.  After rewriting, the tool refuses to save any file whose
``ast.dump`` changed — the pass is formatting-only by construction.

Usage::

    python tools/pyfmt.py --check src tests     # list files needing work
    python tools/pyfmt.py src tests benchmarks  # rewrite in place
"""

from __future__ import annotations

import argparse
import ast
import io
import keyword
import pathlib
import sys
import tokenize

LINE_LENGTH = 100
INDENT = "    "

OPENERS = "([{"
CLOSERS = ")]}"
SKIP_TOKENS = (tokenize.NL, tokenize.INDENT, tokenize.DEDENT, tokenize.ENCODING, tokenize.ENDMARKER)


def logical_lines(src: str):
    """Group tokens into logical lines (terminated by NEWLINE)."""
    toks = list(tokenize.generate_tokens(io.StringIO(src).readline))
    cur = []
    for t in toks:
        if t.type in SKIP_TOKENS:
            if t.type == tokenize.NL and cur:
                cur.append(t)
            continue
        cur.append(t)
        if t.type == tokenize.NEWLINE:
            yield cur
            cur = []


def join_fragments(fragments) -> str:
    """Collapse stripped physical-line fragments into one line, preserving
    the original intra-line spacing and inserting separators only where the
    line break was."""
    out = ""
    for i, frag in enumerate(fragments):
        frag = frag.rstrip() if i == 0 else frag.strip()
        if frag.endswith("\\"):  # melt backslash continuations on join
            frag = frag[:-1].rstrip()
        if not frag:
            continue
        if not out:
            out = frag
            continue
        if out.endswith(",") and frag[0] in CLOSERS:
            out = out[:-1]  # magic comma melts when the bracket collapses
        if out[-1] in OPENERS + "." or frag[0] in CLOSERS + ",:.":
            out += frag
        else:
            out += " " + frag
    return out


def drop_redundant_parens(line: str) -> str:
    """``x = (expr)`` / ``return (expr)`` -> drop the wrapping pair when it
    is a single matched group spanning the whole tail."""
    for marker in ("= (", "return ("):
        i = line.find(marker)
        if i < 0 or not line.endswith(")"):
            continue
        start = i + len(marker) - 1
        depth = 0
        for j in range(start, len(line)):
            if line[j] in OPENERS:
                depth += 1
            elif line[j] in CLOSERS:
                depth -= 1
                if depth == 0:
                    if j == len(line) - 1 and not line[start + 1 :].strip().startswith(
                        ("yield", "await")
                    ):
                        inner = line[start + 1 : -1].strip()
                        # keep parens around tuples / generator expressions
                        d = 0
                        bare_comma = False
                        for ch_i, ch in enumerate(inner):
                            if ch in OPENERS:
                                d += 1
                            elif ch in CLOSERS:
                                d -= 1
                            elif ch == "," and d == 0:
                                bare_comma = True
                        if not bare_comma and " for " not in inner:
                            return line[: i + len(marker) - 1] + inner
                    break
    return line


class Logical:
    """One logical line plus the structural facts the rewrites need."""

    def __init__(self, tokens, lines):
        self.tokens = [t for t in tokens if t.type not in (tokenize.NL, tokenize.NEWLINE)]
        self.rows = sorted({t.start[0] for t in self.tokens})
        self.first_row = self.rows[0]
        self.last_row = max(t.end[0] for t in self.tokens)
        first_line = lines[self.first_row - 1]
        self.indent = first_line[: len(first_line) - len(first_line.lstrip())]
        self.has_comment = any(t.type == tokenize.COMMENT for t in tokens)
        self.has_multiline_string = any(
            t.type == tokenize.STRING and t.end[0] > t.start[0] for t in self.tokens
        )
        self.has_implicit_concat = any(
            a.type == tokenize.STRING and b.type == tokenize.STRING
            for a, b in zip(self.tokens, self.tokens[1:])
        )
        self.has_lambda = any(t.type == tokenize.NAME and t.string == "lambda" for t in self.tokens)
        self.magic_outer, self.magic_nested = self._magic_commas()

    def _magic_commas(self):
        """(outer_has_magic, nested_has_magic) — a 1-tuple's syntactic
        trailing comma (paren group, one element, opener not a call) does
        not count as magic."""
        stack = []  # (open_idx, depth_when_opened, n_commas, last_idx)
        outer = nested = False
        toks = self.tokens
        for i, t in enumerate(toks):
            if t.type != tokenize.OP:
                continue
            if t.string in OPENERS:
                stack.append([i, len(stack), 0])
            elif t.string == "," and stack:
                stack[-1][2] += 1
            elif t.string in CLOSERS and stack:
                open_idx, depth, n_commas = stack.pop()
                if i == open_idx + 1 or toks[i - 1].string != ",":
                    continue
                if toks[open_idx].string == "(" and n_commas == 1:
                    prev = toks[open_idx - 1] if open_idx else None
                    is_call = prev is not None and (
                        (prev.type == tokenize.NAME and not keyword.iskeyword(prev.string))
                        or (prev.type == tokenize.OP and prev.string in CLOSERS + "]")
                    )
                    if not is_call:
                        continue  # 1-tuple: comma is syntax, not magic
                if depth == 0:
                    outer = True
                else:
                    nested = True
        return outer, nested

    @property
    def untouchable(self) -> bool:
        return (
            self.has_comment
            or self.has_multiline_string
            or self.has_implicit_concat
            or self.has_lambda
        )

    def outer_bracket(self):
        """(open_idx, close_idx) of the single outermost bracket group, or
        None when there are zero or several top-level groups."""
        depth = 0
        open_idx = close_idx = None
        groups = 0
        for i, t in enumerate(self.tokens):
            if t.type != tokenize.OP:
                continue
            if t.string in OPENERS:
                if depth == 0:
                    groups += 1
                    if groups > 1:
                        return None
                    open_idx = i
                depth += 1
            elif t.string in CLOSERS:
                depth -= 1
                if depth == 0:
                    close_idx = i
        if open_idx is None or close_idx is None:
            return None
        return open_idx, close_idx

    def collapsed(self, lines) -> str:
        frags = [
            lines[r - 1] if r == self.first_row else lines[r - 1].strip()
            for r in range(self.first_row, self.last_row + 1)
        ]
        return drop_redundant_parens(join_fragments(frags))

    def explode(self, lines):
        """Render the outermost bracket one element per line (with trailing
        commas), or None when any element resists a single-line render."""
        ob = self.outer_bracket()
        if ob is None:
            return None
        open_idx, close_idx = ob
        toks = self.tokens

        def span_text(a, b):
            """Source text covering tokens[a..b], collapsed to one line."""
            r0, c0 = toks[a].start
            r1, c1 = toks[b].end
            if r0 == r1:
                return lines[r0 - 1][c0:c1]
            frags = [lines[r0 - 1][c0:]]
            frags += [lines[r - 1] for r in range(r0 + 1, r1)]
            frags.append(lines[r1 - 1][:c1])
            return join_fragments(frags)

        # split tokens inside the bracket at depth-1 commas
        elems, start, depth = [], open_idx + 1, 0
        for i in range(open_idx + 1, close_idx):
            t = toks[i]
            if t.type != tokenize.OP:
                continue
            if t.string in OPENERS:
                depth += 1
            elif t.string in CLOSERS:
                depth -= 1
            elif t.string == "," and depth == 0:
                if i > start:
                    elems.append((start, i - 1))
                start = i + 1
        if start < close_idx:
            elems.append((start, close_idx - 1))
        if not elems:
            return None
        if toks[open_idx].string == "(" and len(elems) == 1:
            # a single-element paren group: the trailing comma may be a
            # 1-tuple's syntactic comma, not a magic one — only a call
            # (opener preceded by a name/closer that is not a keyword)
            # is safe to explode
            prev = toks[open_idx - 1] if open_idx else None
            is_call = prev is not None and (
                (prev.type == tokenize.NAME and not keyword.iskeyword(prev.string))
                or (prev.type == tokenize.OP and prev.string in CLOSERS)
            )
            if not is_call:
                return None

        head = span_text(0, open_idx)
        tail = span_text(close_idx, len(toks) - 1)
        body = []
        for a, b in elems:
            text = span_text(a, b)
            line = self.indent + INDENT + text + ","
            if len(line) > LINE_LENGTH:
                return None  # element needs a recursive split: leave for hand work
            body.append(line)
        out = [self.indent + head] + body + [self.indent + tail]
        if any(len(ln) > LINE_LENGTH for ln in (out[0], out[-1])):
            return None
        return out


def normalize_strings(src: str) -> str:
    """Simple single-quoted strings -> double quotes (ruff default)."""
    out = []
    toks = list(tokenize.generate_tokens(io.StringIO(src).readline))
    lines = src.splitlines(keepends=True)
    repl = []  # (row, col_start, col_end, new_text)
    for t in toks:
        if t.type != tokenize.STRING or t.start[0] != t.end[0]:
            continue
        s = t.string
        body_at = 0
        while body_at < len(s) and s[body_at] in "rRbBuUfF":
            body_at += 1
        quote = s[body_at:]
        if not quote.startswith("'") or quote.startswith("'''"):
            continue
        inner = quote[1:-1]
        if '"' in inner or "\\" in inner:
            continue
        repl.append((t.start[0], t.start[1], t.end[1], s[:body_at] + '"' + inner + '"'))
    if not repl:
        return src
    for row, c0, c1, new in sorted(repl, reverse=True):
        ln = lines[row - 1]
        lines[row - 1] = ln[:c0] + new + ln[c1:]
    return "".join(lines)


def format_source(src: str) -> str:
    src = normalize_strings(src)
    lines = src.splitlines()
    try:
        lls = [Logical(toks, lines) for toks in logical_lines(src)]
    except (tokenize.TokenError, IndentationError):
        return src
    for ll in reversed(lls):  # bottom-up keeps earlier row numbers valid
        if ll.untouchable:
            continue
        multi = ll.last_row > ll.first_row
        if multi and not (ll.magic_outer or ll.magic_nested):
            one = ll.collapsed(lines)
            if len(one) <= LINE_LENGTH:
                lines[ll.first_row - 1 : ll.last_row] = [one]
                continue
        overflow = not multi and len(lines[ll.first_row - 1]) > LINE_LENGTH
        if (ll.magic_outer or overflow) and not ll.magic_nested:
            exploded = ll.explode(lines)
            if exploded is not None:
                current = lines[ll.first_row - 1 : ll.last_row]
                if current != exploded:
                    lines[ll.first_row - 1 : ll.last_row] = exploded
    out = "\n".join(ln.rstrip() for ln in lines)
    return out.rstrip("\n") + "\n"


def run(paths, *, check: bool, verbose: bool) -> int:
    changed = []
    for root in paths:
        p = pathlib.Path(root)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            src = f.read_text()
            new = format_source(src)
            if new == src:
                continue
            try:
                same = ast.dump(ast.parse(new)) == ast.dump(ast.parse(src))
            except SyntaxError:
                same = False
            if not same:  # formatting-only guarantee
                print(f"pyfmt: SKIP {f} (AST changed — bug guard)", file=sys.stderr)
                continue
            changed.append(str(f))
            if verbose:
                print(f"pyfmt: {'would reformat' if check else 'reformatted'} {f}")
            if not check:
                f.write_text(new)
    n = len(changed)
    mode = "would reformat" if check else "reformatted"
    print(f"pyfmt: {n} file{'s' * (n != 1)} {mode}")
    return 1 if (check and changed) else 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="files or directories to format")
    ap.add_argument("--check", action="store_true", help="report, do not rewrite")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    return run(args.paths, check=args.check, verbose=args.verbose)


if __name__ == "__main__":
    sys.exit(main())
