"""Render dry-run/roofline tables into EXPERIMENTS.md (between markers).

Run: PYTHONPATH=src python tools/render_experiments.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.roofline.analysis import analyze_record  # noqa: E402

DIR = "results/dryrun"


def load():
    recs = []
    for f in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def dryrun_summary(recs):
    lines = [
        "| arch | shape | mesh | status | temp GiB/dev | compile s |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r.get("shape", ""), r["mesh"])):
        if r["status"] == "ok":
            t = r["memory"]["temp_bytes"] / 2**30
            lines.append(
                f"| {r['arch']} | {r.get('shape','')} | {r['mesh']} | ok "
                f"| {t:.2f} | {r.get('compile_s','')} |"
            )
        elif r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r.get('shape','')} | {r['mesh']} | "
                f"skipped ({r['reason'].split(':')[0]}) | — | — |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r.get('shape','')} | {r['mesh']} | "
                f"**ERROR** | — | — |"
            )
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    er = sum(r["status"] == "error" for r in recs)
    lines.append("")
    lines.append(f"**{ok} ok / {sk} skipped (documented) / {er} errors.**")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| useful | roofline frac | one-line bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        "memory": "HBM-bound: fuse/reshard to cut bytes (XLA:CPU fusion under-counts vs TPU; upper bound)",
        "collective": "ICI-bound: overlap or shrink gathers (ring/pipelined modes, grad compression)",
        "compute": "MXU-bound: already near roofline for this shape",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r.get("shape", ""), r["mesh"])):
        t = analyze_record(r)
        if t is None:
            continue
        lines.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {t.compute_s:.4f} | {t.memory_s:.4f} "
            f"| {t.collective_s:.4f} | {t.dominant} | {t.useful_ratio:.2f} "
            f"| {100*t.roofline_fraction:.1f}% | {notes[t.dominant]} |"
        )
    return "\n".join(lines)


def splice(text, marker, payload):
    tag = f"<!-- {marker} -->"
    if tag not in text:
        raise SystemExit(f"marker {marker} missing")
    return text.replace(tag, tag + "\n\n" + payload)


def main():
    recs = load()
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    # drop any previously rendered content after markers? keep simple: the
    # file in git keeps markers pristine; this script is run once per update.
    text = splice(text, "DRYRUN_SUMMARY", dryrun_summary(recs))
    text = splice(text, "ROOFLINE_TABLE", roofline_table(recs))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"rendered {len(recs)} records into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
