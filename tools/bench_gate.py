"""Benchmark regression gate: hold the line on the tracked BENCH_*.json.

Compares freshly-emitted benchmark JSONs (CI runs the benches in --smoke
mode) against the baselines tracked at the repo root, metric by metric,
and fails the build when a metric regresses beyond its class tolerance:

  * **structural** metrics (padding-waste fractions, bucket-slot waste,
    unique-table / reuse ratios, node and tile counts) are machine- and
    load-independent — they must match the baseline near-exactly, and an
    increase means a PR gave back layout or dedup ground that PR 1-3 /
    §14 earned;
  * **timing** metrics (``*_us``/``iter_us``) and timing-derived speedups
    vary with the host, so they only gate at a loose multiplicative
    factor (default 4x) — catching order-of-magnitude cliffs, not noise;
  * a baseline/fresh pair whose ``smoke``/``backend`` flags differ is not
    comparable at all (graph sizes, template sets, and most "structural"
    values change with the mode), so the file fails with ONE actionable
    row: regenerate the tracked baseline with ``--smoke`` — never loosen
    the per-metric tolerances to paper over a mode mismatch.

Usage (what the CI step runs after saving the tracked baselines aside):

    cp BENCH_*.json /tmp/bench-baseline/
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke  # etc.
    python tools/bench_gate.py --baseline /tmp/bench-baseline --fresh .

Exit code 1 iff any metric FAILs; the diff table always prints.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# metric classification by leaf key (substring match, checked in order)
#: robustness metrics (bench_sparsity's checkpoint section): save/restore
#: overhead of the §16 estimator state.  Host-dependent like timings, so
#: they gate at the same loose multiplicative factor — the class exists so
#: a checkpoint-cost cliff fails with its own label, not as generic timing
ROBUSTNESS_KEYS = ("ckpt_",)
TIMING_KEYS = ("_us", "iter_us", "_s")
#: higher-is-better metrics: timing-derived speedups, plus the counting
#: service's reuse signals (bench_service's plan-cache hit rate and
#: pass-coalescing factor) — a drop means cross-request amortization
#: regressed, an increase is pure win and must never fail the gate
HIGHER_BETTER_KEYS = ("speedup", "hit_rate", "coalescing")
#: §20 serving-robustness metrics (bench_service's hardening section):
#: cancel latency is a responsiveness timing — how fast a mid-stream
#: ``ticket.cancel()`` turns terminal under a running driver — host-
#: dependent, so it gates lower-is-better at the loose timing factor but
#: under its own label (a cancel-responsiveness cliff should not read as
#: generic timing noise)
SERVICE_LATENCY_KEYS = ("svc_cancel",)
#: the shed rate is deterministic admission math (bounded queue of N,
#: shed-oldest, M scripted submits) — machine-independent, so it holds
#: near-exactly like the layout metrics
SERVICE_STRUCTURAL_KEYS = ("svc_shed",)
STRUCTURAL_KEYS = (
    "pad_frac",
    "waste",
    "ratio",
    "imbalance",
    "slots",
    "num_tiles",
    "max_bucket",
    "mean_bucket",
    "bytes",
    "nodes",
    "internal",
    "max_deg",
    "directed_edges",
    "chain_",
    "dag_",
    # sparsity signals (bench_sparsity / spmm auto): measured from seeded
    # graph structure, so they are machine-independent like the layouts
    "density",
    # treewidth-2 bag-program metrics (bench_multi_template's bags
    # section, DESIGN.md §19): interning counts and pinned-apex table
    # widths are pure compile-time math — any growth is a front-end or
    # layout regression (timing keys like bag_shared_us still classify
    # as timing via the _us suffix, which is checked first)
    "bag_",
    # §18 narrow-wire volume (bench_load_balance / bench_sparsity): the
    # per-wire exchange-bytes and wire-ratio keys ride the "bytes"/"ratio"
    # substrings above — deterministic plan math, held lower-is-better so
    # a PR can never quietly fatten the wire
)
# context keys that must match for a file's metrics to be comparable at all
META_KEYS = ("smoke", "backend")


def classify(key: str):
    if any(s in key for s in SERVICE_LATENCY_KEYS):
        return "svc_latency"
    if any(s in key for s in SERVICE_STRUCTURAL_KEYS):
        return "structural"
    if any(s in key for s in HIGHER_BETTER_KEYS):
        return "speedup"
    if any(key.startswith(s) for s in ROBUSTNESS_KEYS):
        return "robustness"
    if key.endswith(TIMING_KEYS) or key == "us":
        return "timing"
    if any(s in key for s in STRUCTURAL_KEYS):
        return "structural"
    return None  # metadata / unclassified: not gated


def leaves(obj, prefix=""):
    """Flatten nested dicts to {dotted.path: numeric leaf}."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(leaves(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def compare_file(name, base, fresh, *, struct_rtol: float, timing_factor: float):
    """Yields (path, class, baseline, fresh, status, note) rows."""
    mismatch = [k for k in META_KEYS if base.get(k) != fresh.get(k)]
    if mismatch:
        # nothing in the two files is comparable; fail once, actionably
        for k in mismatch:
            yield (
                k,
                "-",
                base.get(k),
                fresh.get(k),
                "FAIL",
                "baseline/fresh emitted under different modes — regenerate "
                "the tracked baseline with --smoke and commit it",
            )
        return
    b_leaves = leaves(base)
    f_leaves = leaves(fresh)
    for path in sorted(b_leaves):
        if path not in f_leaves:
            yield (
                path,
                "-",
                b_leaves[path],
                None,
                "MISSING",
                "metric dropped from fresh emit",
            )
            continue
        cls = classify(path.rsplit(".", 1)[-1])
        bv, fv = b_leaves[path], f_leaves[path]
        if cls is None:
            continue
        if cls in ("timing", "robustness", "svc_latency"):
            ok = fv <= bv * timing_factor
            note = f"<= {timing_factor:.1f}x baseline"
        elif cls == "speedup":
            ok = fv >= bv / timing_factor
            note = f">= baseline / {timing_factor:.1f}"
        else:  # structural: near-exact, lower-or-equal is always fine
            ok = fv <= bv * (1.0 + struct_rtol) + 1e-9
            note = f"<= baseline * {1.0 + struct_rtol:.2f}"
        yield (path, cls, bv, fv, "ok" if ok else "FAIL", note)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="directory holding the tracked BENCH_*.json")
    ap.add_argument(
        "--fresh", default=".", help="directory holding the freshly-emitted BENCH_*.json"
    )
    ap.add_argument(
        "--struct-rtol",
        type=float,
        default=0.05,
        help="allowed relative worsening of structural metrics",
    )
    ap.add_argument(
        "--timing-factor",
        type=float,
        default=4.0,
        help="allowed multiplicative timing regression",
    )
    args = ap.parse_args(argv)

    names = sorted(
        os.path.basename(p)
        for p in glob.glob(os.path.join(args.baseline, "BENCH_*.json"))
    )
    if not names:
        print(f"bench-gate: no BENCH_*.json baselines in {args.baseline}")
        return 1
    failures = 0
    compared = 0
    for name in names:
        fresh_path = os.path.join(args.fresh, name)
        if not os.path.exists(fresh_path):
            print(f"bench-gate: {name}: fresh emit missing — FAIL")
            failures += 1
            continue
        with open(os.path.join(args.baseline, name)) as fh:
            base = json.load(fh)
        with open(fresh_path) as fh:
            fresh = json.load(fh)
        rows = list(
            compare_file(
                name,
                base,
                fresh,
                struct_rtol=args.struct_rtol,
                timing_factor=args.timing_factor,
            )
        )
        n_fail = sum(r[4] in ("FAIL", "MISSING") for r in rows)
        n_ok = sum(r[4] == "ok" for r in rows)
        failures += n_fail
        compared += n_ok + n_fail
        print(f"\n{name}: {n_ok} ok, {n_fail} regressed")
        if n_fail == 0:
            continue  # keep green output to the summary line
        header = f"  {'metric':<58} {'class':<10} {'baseline':>12} {'fresh':>12}  status"
        print(header)
        print("  " + "-" * (len(header) - 2))
        fmt = lambda v: f"{v:>12.6g}" if isinstance(v, float) else f"{str(v):>12}"
        for path, cls, bv, fv, status, note in rows:
            mark = "" if status == "ok" else f"  ({note})"
            print(f"  {path:<58} {cls:<10} {fmt(bv)} {fmt(fv)}  {status}{mark}")
    print(f"\nbench-gate: {compared} metrics gated, {failures} regressions")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
